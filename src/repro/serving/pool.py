"""Health-aware request pool, rebuilt on the serving primitives.

This is the PR-7 ``DeploymentPool`` contract (bounded queue, shed at
submit, tick-based age-out, round-robin across ``can_serve()`` members,
``ok/degraded/lost/shed`` result statuses, ``server.pool.*`` metrics) with
its ad-hoc tick loop replaced by the shared serving machinery:

* admission and aging run through one
  :class:`~repro.serving.queue.AdmissionQueue` driven by a **tick clock**
  (``now == self.ticks``), so ``max_wait_ticks`` is just a deadline on
  that clock;
* member selection runs through an
  :class:`~repro.serving.router.AffinityRouter` (health-aware round-robin;
  this pool dispatches opaque args, so no shape key and no affinity —
  the micro-batching farm is the affinity user).

The canonical drain entrypoint is :meth:`drain`;
``runtime.server.DeploymentPool`` keeps the old constructor and
``run_until_drained`` as thin deprecated shims over this class.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.obs import MetricsRegistry
from repro.serving.queue import DONE, AdmissionQueue, ServeRequest, SHED
from repro.serving.router import AffinityRouter, NoServeableMember


@dataclass
class PoolStats:
    """What a :class:`DeploymentPool` run actually did."""

    ticks: int = 0
    submitted: int = 0
    served_ok: int = 0
    served_degraded: int = 0
    shed: int = 0
    lost: int = 0
    max_queue_depth: int = 0


class DeploymentPool:
    """Health-aware serving over a pool of (guarded) deployments.

    The fleet-scale pattern on top of the uniform Deployment contract: each
    member is typically a :class:`~repro.resilience.GuardedDeployment`
    (breaker + canary + fallback), and the pool's job is *admission* and
    *backpressure*:

    * requests land in a bounded queue — a full queue **sheds at submit**
      (bounded backpressure, not an unbounded pile-up or a hard raise);
    * each :meth:`tick` dispatches queued requests round-robin across the
      members whose ``can_serve()`` says they can answer (a quarantined,
      fallback-less member takes no traffic — health-aware admission);
    * with *no* serveable member, the queue ages; requests older than
      ``max_wait_ticks`` are shed — sustained breaker-open turns into
      load-shedding instead of latency creep.

    Members are duck-typed: ``can_serve()``/``call()`` are used when
    present (GuardedDeployment), plain callables serve unconditionally —
    so an unguarded Deployment can stand in a pool too.
    """

    def __init__(self, members, *, max_queue: int = 64,
                 max_wait_ticks: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if not members:
            raise ValueError("DeploymentPool needs at least one member")
        self.max_queue = max_queue
        self.max_wait_ticks = max_wait_ticks
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.ticks = 0
        # the queue ages on the pool's tick counter, not wall time: a
        # request submitted at tick T with max_wait_ticks W carries the
        # absolute deadline T + W on that clock.
        self._queue = AdmissionQueue(max_queue, clock=self._now,
                                     metrics=self.metrics,
                                     name="server.pool.queue")
        self._router = AffinityRouter(members, name="server.pool.router",
                                      metrics=self.metrics)
        self._next_rid = 0
        self.results: Dict[int, dict] = {}

    @property
    def members(self) -> List:
        return self._router.members

    def _now(self) -> float:
        return float(self.ticks)

    def _gauge_depth(self) -> None:
        self.metrics.gauge("server.pool.queue_depth").set(len(self._queue))

    # -- admission ------------------------------------------------------ #
    def submit(self, *args) -> int:
        """Enqueue one request; a full queue sheds it immediately (the
        result records ``status="shed"``). Returns the request id either
        way — the caller learns the outcome from :meth:`result`."""
        rid = self._next_rid
        self._next_rid += 1
        self.metrics.counter("server.pool.submitted").inc()
        deadline = (self.ticks + self.max_wait_ticks
                    if self.max_wait_ticks is not None else None)
        req = ServeRequest(rid=rid, design="pool", window=args,
                           t_submit=float(self.ticks), deadline_s=deadline)
        if not self._queue.offer(req):
            self.metrics.counter("server.pool.shed").inc()
            self.results[rid] = {"rid": rid, "status": "shed",
                                 "reason": "queue_full"}
            return rid
        self._gauge_depth()
        return rid

    def result(self, rid: int) -> Optional[dict]:
        return self.results.get(rid)

    def _serveable(self) -> List[int]:
        return self._router.serveable()

    # -- dispatch ------------------------------------------------------- #
    def tick(self) -> int:
        """One scheduling round: age-shed, then dispatch up to one request
        per serveable member (round-robin). Returns requests served."""
        self.ticks += 1
        self.metrics.counter("server.pool.ticks").inc()
        for req in self._queue.expire():     # deadline == max_wait_ticks
            self.metrics.counter("server.pool.shed").inc()
            self.results[req.rid] = {"rid": req.rid, "status": "shed",
                                     "reason": "max_wait_ticks"}
        healthy = self._serveable()
        self.metrics.gauge("server.pool.healthy_members").set(len(healthy))
        served = 0
        for req in self._queue.take(len(healthy)):
            try:
                member_i, m, _ = self._router.route()
            except NoServeableMember:        # raced to zero members
                self._queue.requeue([req])
                break
            entry = {"rid": req.rid, "member": member_i,
                     "waited_ticks": self.ticks - int(req.t_submit)}
            try:
                if hasattr(m, "call"):
                    res = m.call(*req.window)
                    entry.update(value=res.value, source=res.source,
                                 status=("degraded" if res.degraded
                                         else "ok"))
                else:
                    entry.update(value=m(*req.window), status="ok")
            except Exception as e:           # noqa: BLE001 - request lost
                entry.update(status="lost", error=type(e).__name__)
            self.metrics.counter(f"server.pool.{entry['status']}").inc()
            self.results[req.rid] = entry
            req.status = DONE
            served += 1
        self._gauge_depth()
        return served

    def drain(self, max_ticks: int = 10_000) -> PoolStats:
        """Tick until the queue empties (or nothing can serve and aging
        sheds the rest). Never raises: at ``max_ticks`` the remaining queue
        is shed and the partial stats returned."""
        while len(self._queue) and self.ticks < max_ticks:
            before = len(self._queue)
            self.tick()
            if (len(self._queue) == before and not self._serveable()
                    and self.max_wait_ticks is None):
                break                        # wedged: no member, no age-out
        for req in self._queue.take():
            req.status = SHED
            self.metrics.counter("server.pool.shed").inc()
            self.results[req.rid] = {"rid": req.rid, "status": "shed",
                                     "reason": "drain_truncated"}
        return self.stats()

    # kept as the canonical spelling's alias inside repro.serving; the
    # *deprecated* shim (old import site, warns) lives in runtime.server.
    run_until_drained = drain

    def stats(self) -> PoolStats:
        mx = self.metrics
        g = mx.gauge("server.pool.queue_depth")
        return PoolStats(
            ticks=self.ticks,
            submitted=mx.counter("server.pool.submitted").value,
            served_ok=mx.counter("server.pool.ok").value,
            served_degraded=mx.counter("server.pool.degraded").value,
            shed=mx.counter("server.pool.shed").value,
            lost=mx.counter("server.pool.lost").value,
            max_queue_depth=int(g.max) if g.max is not None else 0)
