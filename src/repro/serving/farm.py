"""The accelerator-farm runtime: queue → micro-batcher → router → pools.

This is the fleet-scale serving layer over the uniform Deployment API
(DESIGN.md §14): many concurrent request streams multiplex onto pools of
deployed accelerators. One :class:`AcceleratorFarm` owns

* a bounded :class:`~repro.serving.queue.AdmissionQueue` with deadlines
  (backpressure at the door, aging into load-shedding);
* a :class:`~repro.serving.batcher.MicroBatcher` that coalesces admitted
  requests per ``(design, window-length bucket)`` and packs each group
  into one padded batch dispatch (pad-ragged-then-dechunk, bit-exact);
* per-design :class:`~repro.serving.router.AffinityRouter`s over pools of
  (typically :class:`~repro.resilience.GuardedDeployment`-wrapped)
  members with compiled-program affinity;
* ``serving.*`` spans, counters and latency histograms
  (:mod:`repro.obs`) — p50/p99 request latency, batch fill, queue wait.

Requests admitted to the queue are never silently dropped: every request
reaches exactly one terminal state (``done`` / ``shed`` / ``expired`` /
``failed``), and :meth:`AcceleratorFarm.stats` reconciles the counts — the
CI serving gate asserts ``failed == 0`` and ``admitted == done + expired``.

A failed dispatch (member raised through its guard) is redispatched once
across the remaining healthy members before its requests are marked
``failed`` — farm-level routing around a sick member composes with the
member-level retry/breaker/fallback guards of PR 7.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import MetricsRegistry, get_tracer
from repro.serving.batcher import MicroBatch, MicroBatcher
from repro.serving.queue import (DONE, EXPIRED, FAILED, AdmissionQueue,
                                 ServeRequest, SHED)
from repro.serving.router import AffinityRouter, NoServeableMember


@dataclass(frozen=True)
class FarmConfig:
    """The farm's knobs, one validated frozen dataclass."""

    max_queue: int = 4096            # admission bound (backpressure)
    max_batch: int = 64              # rows per dispatch
    max_wait_s: float = 0.002        # partial-batch linger before flushing
    pad_batch: bool = True           # quantize B to powers of two (no
    #                                  retrace under mixed batch sizes)

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, "
                             f"got {self.max_wait_s}")


@dataclass
class DesignPool:
    """One served design family: the deployments (replicas) behind it and
    the window lengths its lowered variants accept.

    ``members`` maps each registered window length to the replica list
    lowered *at* that length (a fixed-window accelerator only accepts its
    own ``(B, L, F)``). ``flops_per_window`` / ``energy_per_window_j`` per
    length feed the loadgen's GOP/J accounting (both deterministic: the op
    count and the cycle model, not wall clock).
    """

    family: str
    members: Dict[int, List]                      # bucket length -> replicas
    flops_per_window: Dict[int, float] = field(default_factory=dict)
    energy_per_window_j: Dict[int, float] = field(default_factory=dict)

    def __post_init__(self):
        if not self.members:
            raise ValueError(f"design {self.family!r} has no members")
        for ln, reps in self.members.items():
            if not reps:
                raise ValueError(
                    f"design {self.family!r} bucket {ln} has no replicas")

    @property
    def window_lengths(self) -> Tuple[int, ...]:
        return tuple(sorted(self.members))


@dataclass
class FarmStats:
    """What the farm actually did, reconciled from its metrics."""

    submitted: int = 0
    admitted: int = 0
    shed: int = 0                    # at the door (queue full / no bucket)
    expired: int = 0                 # deadline passed while queued
    done: int = 0
    failed: int = 0                  # every redispatch exhausted
    dispatches: int = 0
    redispatches: int = 0
    windows_dispatched: int = 0      # padded rows included
    affinity_hits: int = 0
    affinity_misses: int = 0
    max_queue_depth: int = 0
    latency_s: Dict[str, float] = field(default_factory=dict)
    queue_wait_s: Dict[str, float] = field(default_factory=dict)
    batch_fill: Dict[str, float] = field(default_factory=dict)
    batch_size: Dict[str, float] = field(default_factory=dict)
    per_design: Dict[str, dict] = field(default_factory=dict)

    def to_dict(self) -> dict:
        from dataclasses import asdict

        return asdict(self)


class AcceleratorFarm:
    """Queue + batcher + affinity-routed pools, one tick loop.

    ``submit`` is the only producer API; :meth:`tick` is one scheduling
    round (expire → drain → batch → dispatch → de-chunk);
    :meth:`run_until_drained` ticks with ``flush=True`` until the queue
    empties. The clock and metrics registry are injectable so latency
    histograms replay exactly under test.
    """

    def __init__(self, pools: Sequence[DesignPool],
                 cfg: FarmConfig = FarmConfig(), *,
                 clock=time.perf_counter,
                 metrics: Optional[MetricsRegistry] = None):
        if not pools:
            raise ValueError("AcceleratorFarm needs at least one DesignPool")
        self.cfg = cfg
        self.clock = clock
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.pools: Dict[str, DesignPool] = {}
        self.routers: Dict[Tuple[str, int], AffinityRouter] = {}
        for pool in pools:
            if pool.family in self.pools:
                raise ValueError(f"duplicate design {pool.family!r}")
            self.pools[pool.family] = pool
            for ln, reps in pool.members.items():
                self.routers[(pool.family, ln)] = AffinityRouter(
                    reps, name=f"serving.router.{pool.family}.{ln}",
                    metrics=self.metrics)
        self.queue = AdmissionQueue(cfg.max_queue, clock=clock,
                                    metrics=self.metrics)
        self.batcher = MicroBatcher(
            buckets={f: p.window_lengths for f, p in self.pools.items()},
            max_batch=cfg.max_batch, max_wait_s=cfg.max_wait_s,
            pad_batch=cfg.pad_batch)
        self._next_rid = 0
        self.requests: Dict[int, ServeRequest] = {}
        self.ticks = 0

    # -- producer API --------------------------------------------------- #
    def submit(self, design: str, window, *,
               deadline_s: Optional[float] = None,
               timeout_s: Optional[float] = None) -> int:
        """Enqueue one window for ``design``. Returns the request id; the
        outcome (including an immediate shed) is read via :meth:`result`.

        ``deadline_s`` is absolute on the farm clock; ``timeout_s`` is the
        relative convenience spelling (now + timeout).
        """
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        if timeout_s is not None:
            deadline_s = now + timeout_s if deadline_s is None \
                else min(deadline_s, now + timeout_s)
        req = ServeRequest(rid=rid, design=design, window=window,
                           t_submit=now, deadline_s=deadline_s)
        self.requests[rid] = req
        self.metrics.counter("serving.submitted").inc()
        if design not in self.pools:
            req.status = SHED
            req.error = (f"unknown design {design!r}; registered: "
                         f"{sorted(self.pools)}")
            self.metrics.counter("serving.queue.shed_full").inc()
            return rid
        try:
            self.batcher.bucket(design, int(np.asarray(window).shape[0]))
        except ValueError as e:          # no lowered variant fits: shed now
            req.status = SHED
            req.error = str(e)
            self.metrics.counter("serving.queue.shed_full").inc()
            return rid
        self.queue.offer(req)
        return rid

    def result(self, rid: int) -> Optional[ServeRequest]:
        return self.requests.get(rid)

    # -- scheduling ----------------------------------------------------- #
    def tick(self, *, flush: bool = False) -> int:
        """One scheduling round; returns requests completed this round."""
        self.ticks += 1
        self.metrics.counter("serving.ticks").inc()
        trc = get_tracer()
        with trc.span("serving.tick", tick=self.ticks,
                      queue_depth=len(self.queue)):
            self.queue.expire()
            taken = self.queue.take()
            if not taken:
                return 0
            batches, lingering = self.batcher.form(
                taken, now=self.clock(), flush=flush)
            self.queue.requeue(lingering)
            completed = 0
            for batch in batches:
                completed += self._dispatch(batch)
            return completed

    def _dispatch(self, batch: MicroBatch) -> int:
        """Route one packed batch, execute, de-chunk; redispatch once on
        member failure before marking the batch's requests failed.

        Deadlines are re-checked here: a request can expire *between*
        ``queue.take()`` and dispatch (batch forming takes wall time, and
        a lingering partial batch may carry old requests), which
        ``queue.expire`` can no longer catch. Expired rows stay in the
        packed array (row i ↔ request i alignment is the de-chunk
        contract) but are marked terminal before the dispatch and never
        receive a result; they count under the same
        ``serving.queue.expired`` counter as queue-side expiry, keeping
        the ``admitted == done + expired`` reconciliation exact.
        """
        mx = self.metrics
        trc = get_tracer()
        arr = batch.array
        t_dispatch = self.clock()
        live: List[ServeRequest] = []
        for req in batch.requests:
            if req.deadline_s is not None and t_dispatch >= req.deadline_s:
                req.status = EXPIRED     # missed between take() and here
                req.error = "deadline"
                req.t_done = t_dispatch
                mx.counter("serving.queue.expired").inc()
            else:
                live.append(req)
        if not live:
            return 0
        for req in live:                 # queued -> on the wire
            mx.histogram("serving.queue_wait_s").observe(
                t_dispatch - req.t_submit)
        tried: Tuple[int, ...] = ()
        router = self.routers[(batch.design, batch.bucket_len)]
        for attempt in range(2):
            try:
                idx, member, hit = router.route(arr.shape, arr.dtype,
                                                exclude=tried)
            except NoServeableMember as e:
                return self._fail(batch, type(e).__name__)
            try:
                with trc.span("serving.dispatch", design=batch.design,
                              bucket=batch.bucket_len,
                              batch=int(arr.shape[0]),
                              fill=round(batch.fill, 3), member=idx,
                              affinity_hit=hit, attempt=attempt):
                    res = member.call(arr) if hasattr(member, "call") \
                        else member(arr)
                out = res.value if hasattr(res, "value") else res
                out = np.asarray(out)
            except Exception as e:       # noqa: BLE001 - route around it
                tried = tried + (idx,)
                mx.counter("serving.redispatches").inc()
                if attempt == 1:
                    return self._fail(batch, type(e).__name__)
                continue
            now = self.clock()
            mx.counter("serving.dispatches").inc()
            mx.counter("serving.windows_dispatched").inc(int(arr.shape[0]))
            mx.histogram("serving.batch_fill").observe(batch.fill)
            mx.histogram("serving.batch_size").observe(len(live))
            from repro.serving.batcher import unpack

            unpack(batch, out)           # skips terminal (expired) rows
            for req in live:
                req.status = DONE
                req.t_done = now
                req.member = idx
                req.batch_size = int(arr.shape[0])
                mx.counter("serving.done").inc()
                mx.counter(f"serving.done.{batch.design}").inc()
                mx.histogram("serving.latency_s").observe(
                    now - req.t_submit)
                mx.histogram(
                    f"serving.latency_s.{batch.design}").observe(
                    now - req.t_submit)
            return len(live)
        return 0                         # unreachable; keeps mypy honest

    def _fail(self, batch: MicroBatch, error: str) -> int:
        now = self.clock()
        for req in batch.requests:
            if req.terminal:             # e.g. expired at dispatch time
                continue
            req.status = FAILED
            req.error = error
            req.t_done = now
            self.metrics.counter("serving.failed").inc()
        return 0

    def run_until_drained(self, max_ticks: int = 100_000) -> "FarmStats":
        """Tick (flushing partial batches) until the queue empties."""
        ticks = 0
        while len(self.queue):
            self.tick(flush=True)
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError(
                    f"farm did not drain within max_ticks={max_ticks}: "
                    f"{len(self.queue)} queued; stats={self.stats()}")
        return self.stats()

    # -- accounting ----------------------------------------------------- #
    def stats(self) -> FarmStats:
        mx = self.metrics

        def c(name):
            return mx.counter(name).value

        g = mx.gauge("serving.queue.depth")
        per_design = {}
        for family, pool in self.pools.items():
            h = mx.histogram(f"serving.latency_s.{family}")
            per_design[family] = {
                "done": c(f"serving.done.{family}"),
                "window_lengths": list(pool.window_lengths),
                "latency_s": h.summary() if h.count else {},
            }
        return FarmStats(
            submitted=c("serving.submitted"),
            admitted=c("serving.queue.admitted"),
            shed=c("serving.queue.shed_full"),
            expired=c("serving.queue.expired"),
            done=c("serving.done"),
            failed=c("serving.failed"),
            dispatches=c("serving.dispatches"),
            redispatches=c("serving.redispatches"),
            windows_dispatched=c("serving.windows_dispatched"),
            affinity_hits=sum(
                v.value for k, v in mx.counters.items()
                if k.endswith(".affinity_hit")),
            affinity_misses=sum(
                v.value for k, v in mx.counters.items()
                if k.endswith(".affinity_miss")),
            max_queue_depth=int(g.max) if g.max is not None else 0,
            latency_s=mx.histogram("serving.latency_s").summary(),
            queue_wait_s=mx.histogram("serving.queue_wait_s").summary(),
            batch_fill=mx.histogram("serving.batch_fill").summary(),
            batch_size=mx.histogram("serving.batch_size").summary(),
            per_design=per_design)
