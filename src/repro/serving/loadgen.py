"""Deterministic mixed-traffic load generator for the accelerator farm.

Replays seeded heavy traffic — the ROADMAP's "millions of users" scaled to
a benchmarkable slice — against a farm built from the repo's two paper
workloads: the LSTM traffic predictor (``configs/elastic_lstm``) and the
conv1d sensor stack (``configs/elastic_conv1d``), each deployed at several
window lengths (the batcher's buckets) with ``--replicas`` pool members per
bucket. Requests draw design, window length and window contents from one
``numpy`` generator seeded by ``--seed``, so a run is replayable
bit-for-bit; under an injected :class:`~repro.resilience.faults.VirtualClock`
even the latency histograms replay exactly (the determinism test).

Arrival modes:

* ``closed`` — submit a wave, drain it, repeat: bounded concurrency, the
  classic closed-loop throughput probe;
* ``open``  — submit the next wave every tick regardless of backlog: the
  bounded admission queue is the only brake, so overload shows up as
  shedding/expiry instead of latency creep.

Reported per design via the farm's ``serving.*`` histograms: p50/p99
latency, windows/s, and GOP/J — energy from the cycle-accurate model
(``resources.estimate`` × ``HWSpec.energy_j``), the same accounting the
measurement stage uses, so the figure is deterministic and comparable to
the paper's Table I.

CLI (the README quickstart and the CI serving smoke)::

    python -m repro.serving.loadgen --arch lstm,conv1d --requests 512 \
        --out BENCH_serving.json --p99-bound 0.5

Exits nonzero when a request admitted to the queue fails to reach
``done``/``expired`` (dropped after admission) or the p99 bound is blown.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import MetricsRegistry
from repro.serving.farm import AcceleratorFarm, DesignPool, FarmConfig
from repro.serving.queue import DONE

#: per-design input feature width (lstm is univariate, conv1d is 3-axis IMU)
ARCH_FEATURES = {"lstm": 1, "conv1d": 3}
#: default window-length buckets: each length is a separately lowered design
DEFAULT_BUCKETS: Dict[str, Tuple[int, ...]] = {
    "lstm": (6, 12), "conv1d": (16, 24)}


def _variant_cfg(arch: str, seq_len: int):
    """The paper workload's ModelConfig re-lowered at ``seq_len``."""
    if arch == "lstm":
        from repro.configs.elastic_lstm import config

        cfg = config()
        return cfg.with_(lstm=dataclasses.replace(cfg.lstm,
                                                  seq_len=seq_len))
    if arch == "conv1d":
        from repro.configs.elastic_conv1d import config

        cfg = config()
        return cfg.with_(conv1d=dataclasses.replace(cfg.conv1d,
                                                    seq_len=seq_len))
    raise ValueError(f"unknown arch {arch!r}; known: "
                     f"{sorted(ARCH_FEATURES)}")


def build_design(arch: str, seq_lens: Sequence[int], *, replicas: int = 2,
                 seed: int = 0) -> DesignPool:
    """Lower ``arch`` once per window length and replicate each executable
    into a pool (``dataclasses.replace`` re-runs ``__post_init__`` — every
    replica owns a fresh emulator, i.e. its own program cache)."""
    import jax

    from repro.model.layers import init_params
    from repro.rtl.backend import translate_rtl
    from repro.rtl.resources import estimate

    members: Dict[int, List] = {}
    flops_d: Dict[int, float] = {}
    energy_d: Dict[int, float] = {}
    for seq_len in seq_lens:
        cfg = _variant_cfg(arch, seq_len)
        if arch == "lstm":
            from repro.model.lstm import lstm_flops, lstm_schema

            schema, flops = lstm_schema(cfg), float(lstm_flops(cfg))
        else:
            from repro.model.conv1d import conv1d_flops, conv1d_schema

            schema, flops = conv1d_schema(cfg), float(conv1d_flops(cfg))
        params = init_params(schema, jax.random.PRNGKey(seed))
        _, exe = translate_rtl(cfg, params, model_flops=flops)
        rr = estimate(exe.graph, clock_hz=exe.hw.clock_hz or 100e6)
        members[seq_len] = [exe] + [dataclasses.replace(exe)
                                    for _ in range(max(0, replicas - 1))]
        flops_d[seq_len] = flops
        energy_d[seq_len] = exe.hw.energy_j(rr.latency_s, duty=rr.duty)
    return DesignPool(family=arch, members=members,
                      flops_per_window=flops_d,
                      energy_per_window_j=energy_d)


def build_farm(archs: Sequence[str], *, replicas: int = 2,
               buckets: Optional[Dict[str, Tuple[int, ...]]] = None,
               cfg: FarmConfig = FarmConfig(), seed: int = 0,
               clock=time.perf_counter,
               metrics: Optional[MetricsRegistry] = None
               ) -> Tuple[AcceleratorFarm, List[DesignPool]]:
    buckets = buckets if buckets is not None else DEFAULT_BUCKETS
    pools = [build_design(a, buckets[a], replicas=replicas, seed=seed)
             for a in archs]
    return AcceleratorFarm(pools, cfg, clock=clock, metrics=metrics), pools


@dataclass(frozen=True)
class TrafficSpec:
    """One seeded traffic mix: what arrives, how fast, in which loop."""

    archs: Tuple[str, ...] = ("lstm", "conv1d")
    n_requests: int = 512
    wave: int = 64                   # requests submitted per round
    mode: str = "closed"             # "closed" | "open"
    seed: int = 0
    timeout_s: Optional[float] = None    # per-request deadline (open loop)

    def __post_init__(self):
        if self.mode not in ("closed", "open"):
            raise ValueError(f"mode must be 'closed' or 'open', "
                             f"got {self.mode!r}")
        if self.n_requests < 1 or self.wave < 1:
            raise ValueError("n_requests and wave must be >= 1")


def generate_requests(spec: TrafficSpec,
                      buckets: Dict[str, Tuple[int, ...]]
                      ) -> List[Tuple[str, np.ndarray]]:
    """The seeded request tape: ``(design, (T, F) float32 window)`` pairs
    with design mix, ragged window lengths, and contents all drawn from one
    generator — identical tape for identical ``spec``."""
    rng = np.random.default_rng(spec.seed)
    archs = sorted(spec.archs)
    out: List[Tuple[str, np.ndarray]] = []
    for _ in range(spec.n_requests):
        design = archs[int(rng.integers(len(archs)))]
        lens = buckets[design]
        t = int(rng.integers(max(1, min(lens) // 2), max(lens) + 1))
        window = rng.standard_normal(
            (t, ARCH_FEATURES[design])).astype(np.float32) * 0.25
        out.append((design, window))
    return out


def run_loadgen(farm: AcceleratorFarm, pools: Sequence[DesignPool],
                spec: TrafficSpec, *, clock=time.perf_counter) -> dict:
    """Drive one traffic tape through the farm; returns the stats report
    (a JSON-stable dict — identical spec + injected clock ⇒ identical
    report, the determinism contract)."""
    tape = generate_requests(
        spec, {p.family: p.window_lengths for p in pools})
    rid_start = farm._next_rid
    t0 = clock()
    if spec.mode == "closed":
        for i in range(0, len(tape), spec.wave):
            for design, window in tape[i:i + spec.wave]:
                farm.submit(design, window, timeout_s=spec.timeout_s)
            farm.run_until_drained()
    else:                            # open loop: submit every tick, no brake
        i = 0
        while i < len(tape) or len(farm.queue):
            for design, window in tape[i:i + spec.wave]:
                farm.submit(design, window, timeout_s=spec.timeout_s)
            i += spec.wave
            farm.tick(flush=i >= len(tape))
        farm.run_until_drained()
    elapsed = clock() - t0
    # a re-run on a warmed farm reports only ITS OWN requests (rid >=
    # rid_start): latency and throughput come from the request records,
    # not the farm-lifetime histograms, so steady-state runs aren't
    # polluted by an earlier pass's compile-era tail.
    reqs = [r for rid, r in sorted(farm.requests.items())
            if rid >= rid_start]
    return _report(farm, pools, spec, reqs, elapsed)


def _report(farm: AcceleratorFarm, pools: Sequence[DesignPool],
            spec: TrafficSpec, reqs, elapsed_s: float) -> dict:
    from repro.obs import percentile

    def lat_summary(rs) -> dict:
        lats = sorted(r.t_done - r.t_submit for r in rs
                      if r.status == DONE and r.t_done is not None)
        return {"count": len(lats),
                "p50": percentile(lats, 50), "p99": percentile(lats, 99),
                "max": lats[-1] if lats else 0.0}

    done = [r for r in reqs if r.status == DONE]
    by_status: Dict[str, int] = {}
    for r in reqs:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    lat = lat_summary(reqs)
    per_design = {}
    for pool in pools:
        mine = [r for r in reqs if r.design == pool.family]
        fin = [r for r in mine if r.status == DONE]
        flops = sum(pool.flops_per_window.get(r.bucket_len, 0.0)
                    for r in fin)
        energy = sum(pool.energy_per_window_j.get(r.bucket_len, 0.0)
                     for r in fin)
        per_design[pool.family] = {
            "submitted": len(mine),
            "done": len(fin),
            "window_lengths": list(pool.window_lengths),
            "latency_s": lat_summary(mine),
            "flops_dispatched": flops,
            "energy_j": energy,
            "gop_per_j": (flops / 1e9) / energy if energy else 0.0,
        }
    # zero-loss invariant (CI serving gate): after a drain every request
    # is terminal — one stuck in ``queued`` was silently dropped.
    dropped = sum(1 for r in reqs if not r.terminal)
    return {
        "spec": dataclasses.asdict(spec),
        "submitted": len(reqs),
        "by_status": dict(sorted(by_status.items())),
        "elapsed_s": elapsed_s,
        "throughput_windows_per_s": (len(done) / elapsed_s
                                     if elapsed_s > 0 else None),
        "latency_p50_s": lat["p50"],
        "latency_p99_s": lat["p99"],
        "dropped_after_admission": dropped,
        "stats": farm.stats().to_dict(),
        "per_design": per_design,
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.serving.loadgen",
        description="seeded mixed-traffic loadgen for the accelerator farm")
    p.add_argument("--arch", default="lstm,conv1d",
                   help="comma-separated design families "
                        f"(known: {sorted(ARCH_FEATURES)})")
    p.add_argument("--requests", type=int, default=512)
    p.add_argument("--wave", type=int, default=64)
    p.add_argument("--mode", default="closed", choices=("closed", "open"))
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--timeout-s", type=float, default=None)
    p.add_argument("--out", default=None,
                   help="write the stats report JSON here")
    p.add_argument("--p99-bound", type=float, default=None,
                   help="fail (exit 1) when p99 latency exceeds this")
    p.add_argument("--baseline", action="store_true",
                   help="also run the same tape unbatched (max_batch=1) "
                        "and report the batching speedup")
    p.add_argument("--warm", action="store_true",
                   help="run the tape once unreported first so every "
                        "(B, L, F) program is compiled — the reported "
                        "pass then measures steady state, not compiles")
    args = p.parse_args(argv)

    archs = tuple(a.strip() for a in args.arch.split(",") if a.strip())
    spec = TrafficSpec(archs=archs, n_requests=args.requests,
                       wave=args.wave, mode=args.mode, seed=args.seed,
                       timeout_s=args.timeout_s)

    def one_run(max_batch: int, pad_batch: bool) -> dict:
        farm, pools = build_farm(
            archs, replicas=args.replicas, seed=args.seed,
            cfg=FarmConfig(max_batch=max_batch, pad_batch=pad_batch),
            metrics=MetricsRegistry())
        if args.warm:                # compile pass; its requests unreported
            run_loadgen(farm, pools, spec)
        return run_loadgen(farm, pools, spec)

    report = one_run(args.max_batch, True)
    if args.baseline:
        base = one_run(1, False)
        report["unbatched"] = {
            "throughput_windows_per_s": base["throughput_windows_per_s"],
            "latency_p99_s": base["latency_p99_s"],
        }
        tput, base_tput = (report["throughput_windows_per_s"],
                           base["throughput_windows_per_s"])
        report["batching_speedup"] = (tput / base_tput
                                      if tput and base_tput else None)

    text = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(text + "\n")
    st = report["by_status"]
    print(f"loadgen: {report['submitted']} submitted, "
          f"{st.get('done', 0)} done, {st.get('shed', 0)} shed, "
          f"{st.get('expired', 0)} expired, {st.get('failed', 0)} failed "
          f"over {report['stats']['dispatches']} dispatches")
    tput = report["throughput_windows_per_s"]
    print(f"  throughput: "
          f"{tput:,.0f} windows/s" if tput else "  throughput: n/a")
    print(f"  latency p50/p99: {report['latency_p50_s'] * 1e6:.0f} / "
          f"{report['latency_p99_s'] * 1e6:.0f} us")
    for fam, d in sorted(report["per_design"].items()):
        print(f"  {fam}: {d['done']} done, {d['gop_per_j']:.2f} GOP/J")
    if report.get("batching_speedup") is not None:
        print(f"  batching speedup vs unbatched: "
              f"{report['batching_speedup']:.1f}x")

    ok = True
    if report["dropped_after_admission"] != 0:
        print(f"FAIL: {report['dropped_after_admission']} requests "
              "dropped after admission", file=sys.stderr)
        ok = False
    if st.get("failed", 0) != 0:
        print(f"FAIL: {st['failed']} requests failed", file=sys.stderr)
        ok = False
    if (args.p99_bound is not None
            and report["latency_p99_s"] > args.p99_bound):
        print(f"FAIL: p99 latency {report['latency_p99_s']:.4f}s exceeds "
              f"bound {args.p99_bound}s", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
