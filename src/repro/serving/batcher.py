"""Dynamic micro-batcher: pack ragged windows, dispatch once, de-chunk.

The RTL designs are fixed-window accelerators — every template bakes its
``seq_len`` into the node (DESIGN.md §9), so a deployment only ever accepts
``(B, L, F)`` batches at its own window length ``L``. Heterogeneous traffic
(windows of varied length ``T``) therefore buckets by length: each design
family registers the window lengths its deployed variants were lowered at,
a ``T``-sample request routes to the smallest bucket with ``L >= T``, and
the window is zero-padded from ``T`` to ``L``.

Within a bucket the batcher packs: stack all padded windows along the batch
axis, optionally pad the batch dimension up to the next power of two
(``pad_batch=True``) so the emulator's compiled-program LRU sees a bounded
set of ``(B, L, F)`` shapes and mixed traffic never retraces, then dispatch
the whole block through one ``run_many``-style call and slice each
request's rows back out (:func:`unpack`).

Bit-exactness contract: batch rows are independent in every template (the
``run_many`` property, tested since PR 2), so the de-chunked result of a
packed dispatch is integer-identical to calling the deployment on each
padded window alone. The batcher never changes *what* is computed for a
request — only how many requests share one program dispatch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.queue import ServeRequest


def bucket_for(lengths: Sequence[int], t: int) -> int:
    """The smallest registered window length that fits a ``t``-sample
    window. Raises with the registered lengths when nothing fits."""
    if t < 1:
        raise ValueError(f"window length must be >= 1, got {t}")
    fits = [ln for ln in lengths if ln >= t]
    if not fits:
        raise ValueError(
            f"no window bucket fits length {t}; registered lengths: "
            f"{sorted(lengths)}")
    return min(fits)


def pad_window(x: np.ndarray, length: int) -> np.ndarray:
    """Zero-pad a ``(T, F)`` window to ``(length, F)`` along time."""
    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"window must be (T, F), got shape {x.shape}")
    t = x.shape[0]
    if t > length:
        raise ValueError(f"window length {t} exceeds bucket length {length}")
    if t == length:
        return x
    pad = np.zeros((length - t, x.shape[1]), x.dtype)
    return np.concatenate([x, pad], axis=0)


def padded_batch_size(n: int, max_batch: int) -> int:
    """Next power of two >= n, capped at ``max_batch`` (programs compile
    per total batch size, so quantizing B bounds the program-cache set).

    ``max_batch`` is a hard cap: exactly ``max_batch`` real rows must not
    round up past it (B=64 at cap 64 stays 64), and more rows than the
    cap is a caller error — :func:`pack` splits oversized groups into
    multiple batches *before* sizing each one.
    """
    if n < 1:
        raise ValueError(f"batch must be >= 1, got {n}")
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if n > max_batch:
        raise ValueError(
            f"batch of {n} rows exceeds max_batch={max_batch}; split the "
            "group into multiple dispatches first (pack does)")
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


@dataclass
class MicroBatch:
    """One packed dispatch: ``array`` is ``(B_padded, L, F)`` with the
    first ``len(requests)`` rows real and the rest zero filler."""

    design: str
    bucket_len: int
    requests: List[ServeRequest]
    array: np.ndarray

    @property
    def fill(self) -> float:
        """Real rows / dispatched rows — the padding overhead observable."""
        return len(self.requests) / self.array.shape[0]


def pack(design: str, bucket_len: int, requests: List[ServeRequest], *,
         pad_batch: bool = True, max_batch: int = 64) -> List[MicroBatch]:
    """Pad each request's window to ``bucket_len``, stack along batch, and
    (optionally) pad the batch dimension to a power of two.

    Returns a *list* of batches: a group larger than ``max_batch`` splits
    into ``ceil(n / max_batch)`` dispatches (each at most ``max_batch``
    rows) instead of raising or silently dispatching an over-cap shape
    the program cache was never sized for.
    """
    if not requests:
        raise ValueError("cannot pack an empty batch")
    batches: List[MicroBatch] = []
    for i in range(0, len(requests), max_batch):
        chunk = list(requests[i:i + max_batch])
        rows = [pad_window(np.asarray(r.window, np.float32), bucket_len)
                for r in chunk]
        arr = np.stack(rows, axis=0)
        if pad_batch:
            b = padded_batch_size(len(rows), max_batch)
            if b > len(rows):
                filler = np.zeros((b - len(rows),) + arr.shape[1:],
                                  arr.dtype)
                arr = np.concatenate([arr, filler], axis=0)
        batches.append(MicroBatch(design=design, bucket_len=bucket_len,
                                  requests=chunk, array=arr))
    return batches


def unpack(batch: MicroBatch, outputs) -> None:
    """De-chunk one dispatch: slice row ``i`` of ``outputs`` back onto
    request ``i``. Filler rows are dropped, and rows whose request is
    already terminal (e.g. expired at dispatch time) keep their verdict —
    a missed deadline must not grow a result. Marks nothing terminal
    itself — the farm owns status transitions (it also stamps
    timing/provenance)."""
    out = np.asarray(outputs)
    if out.shape[0] < len(batch.requests):
        raise ValueError(
            f"dispatch returned {out.shape[0]} rows for "
            f"{len(batch.requests)} requests")
    for i, req in enumerate(batch.requests):
        if req.terminal:
            continue
        req.result = out[i]


@dataclass
class MicroBatcher:
    """Groups admitted requests into :class:`MicroBatch` dispatches.

    ``buckets`` maps a design family to the window lengths its deployed
    variants accept (sorted ascending). :meth:`form` greedily fills
    per-``(design, bucket)`` groups: full batches (``max_batch``) always
    flush; partial batches flush when forced (``flush=True``) or when their
    oldest request has lingered past ``max_wait_s`` — the classic dynamic
    batcher latency/throughput dial.
    """

    buckets: Dict[str, Tuple[int, ...]]
    max_batch: int = 64
    max_wait_s: float = 0.002
    pad_batch: bool = True

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        self.buckets = {d: tuple(sorted(ls))
                        for d, ls in self.buckets.items()}
        for d, ls in self.buckets.items():
            if not ls:
                raise ValueError(f"design {d!r} registers no window lengths")

    def bucket(self, design: str, t: int) -> int:
        if design not in self.buckets:
            raise KeyError(f"unknown design {design!r}; registered: "
                           f"{sorted(self.buckets)}")
        return bucket_for(self.buckets[design], t)

    def form(self, requests: List[ServeRequest], *, now: float,
             flush: bool = False
             ) -> Tuple[List[MicroBatch], List[ServeRequest]]:
        """Partition ``requests`` into ready dispatches and leftovers.

        Returns ``(batches, lingering)``: lingering requests go back to the
        queue (FIFO order preserved) to accumulate a fuller batch.
        """
        groups: Dict[Tuple[str, int], List[ServeRequest]] = {}
        for req in requests:
            key = (req.design, self.bucket(req.design,
                                           int(np.asarray(req.window).shape[0])))
            req.bucket_len = key[1]
            groups.setdefault(key, []).append(req)
        batches: List[MicroBatch] = []
        lingering: List[ServeRequest] = []
        for (design, ln), group in groups.items():
            n_full = (len(group) // self.max_batch) * self.max_batch
            if n_full:                   # full batches always flush
                batches.extend(pack(design, ln, group[:n_full],
                                    pad_batch=self.pad_batch,
                                    max_batch=self.max_batch))
                group = group[n_full:]
            if group:
                waited = now - min(r.t_submit for r in group)
                if flush or waited >= self.max_wait_s:
                    batches.extend(pack(design, ln, group,
                                        pad_batch=self.pad_batch,
                                        max_batch=self.max_batch))
                else:
                    lingering.extend(group)
        # keep queue order stable for the requeue
        lingering.sort(key=lambda r: r.rid)
        return batches, lingering
