"""Program-cache affinity routing across a pool of deployments.

Every pool member owns its own staged executor, and each executor compiles
one program per ``(batch shape, dtype)`` into a small LRU (DESIGN.md §7).
At fleet scale the dominant avoidable cost is *retracing*: dispatching a
shape to a member that has never seen it pays a jit trace + compile, while
the member one slot over already holds the program. The router therefore
routes each packed batch to the member whose compiled-program LRU already
holds that shape key (an **affinity hit**), and only falls back to
health-aware round-robin (the PR-7 ``can_serve`` contract) on a miss — so
steady mixed traffic converges to a stable shape→member assignment and
``RTLEmulator.trace_count`` stops growing.

Members are duck-typed exactly like :class:`~repro.serving.pool`
members: ``can_serve()`` gates admission when present
(:class:`~repro.resilience.GuardedDeployment`), ``holds_program(shape,
dtype)`` answers affinity when present, else the member's ``.emulator``
(:meth:`~repro.rtl.emulator.RTLEmulator.has_program`) is consulted; plain
callables serve unconditionally with no affinity.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.obs import MetricsRegistry, get_metrics


class NoServeableMember(RuntimeError):
    """Every member of the pool is quarantined/open with no fallback."""


def member_holds_program(member, shape, dtype) -> bool:
    """Does ``member`` already hold a compiled program for this key?"""
    holds = getattr(member, "holds_program", None)
    if holds is not None:
        return bool(holds(shape, dtype))
    emu = getattr(member, "emulator", None)
    if emu is not None and hasattr(emu, "has_program"):
        return bool(emu.has_program(shape, dtype))
    return False


class AffinityRouter:
    """Pick a pool member per dispatch: affinity first, health always."""

    def __init__(self, members, *, name: str = "serving.router",
                 metrics: Optional[MetricsRegistry] = None):
        if not members:
            raise ValueError("AffinityRouter needs at least one member")
        self.members = list(members)
        self.name = name
        self._metrics = metrics
        self._rr = 0

    @property
    def metrics(self) -> MetricsRegistry:
        return self._metrics if self._metrics is not None else get_metrics()

    def serveable(self, exclude: Tuple[int, ...] = ()) -> List[int]:
        """Indices of members whose ``can_serve()`` admits traffic now."""
        return [i for i, m in enumerate(self.members)
                if i not in exclude
                and (not hasattr(m, "can_serve") or m.can_serve())]

    def route(self, shape=None, dtype=None, *,
              exclude: Tuple[int, ...] = ()) -> Tuple[int, object, bool]:
        """Returns ``(index, member, affinity_hit)`` for one dispatch.

        ``shape``/``dtype`` key the affinity lookup (omit them for
        shapeless work — pure health-aware round-robin). ``exclude`` skips
        members that already failed this request (redispatch).
        """
        healthy = self.serveable(exclude)
        if not healthy:
            raise NoServeableMember(
                f"{self.name}: no serveable member among "
                f"{len(self.members)} (excluded: {list(exclude)})")
        if shape is not None:
            shape = tuple(int(d) for d in shape)
            for i in healthy:
                if member_holds_program(self.members[i], shape, dtype):
                    self.metrics.counter(f"{self.name}.affinity_hit").inc()
                    return i, self.members[i], True
            self.metrics.counter(f"{self.name}.affinity_miss").inc()
        i = healthy[self._rr % len(healthy)]
        self._rr += 1
        return i, self.members[i], False
