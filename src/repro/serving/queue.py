"""Bounded admission queue with deadlines — the farm's front door.

Fleet-scale serving is an *admission* problem before it is a compute
problem: heavy traffic must meet a bounded queue (backpressure, not an
unbounded pile-up), and a request that can no longer meet its deadline must
be shed *before* it wastes a dispatch slot. :class:`AdmissionQueue` is that
contract, shared by the micro-batching farm (:mod:`repro.serving.farm`) and
the health-aware :class:`~repro.serving.pool.DeploymentPool`:

* :meth:`offer` admits a request or sheds it immediately when the queue is
  at capacity (``status="shed"``, ``serving.queue.shed_full``) — the caller
  always learns the outcome synchronously;
* :meth:`expire` walks the queue and sheds every request whose absolute
  ``deadline_s`` has passed on the queue's injectable clock
  (``status="expired"``, ``serving.queue.expired``) — sustained overload
  turns into load-shedding instead of latency creep;
* :meth:`take` hands admitted requests to the scheduler in FIFO order.

Time comes from an injected callable clock (a
:class:`~repro.resilience.faults.VirtualClock` under test), so deadline
behavior replays exactly.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, List, Optional

from repro.obs import MetricsRegistry, get_metrics

#: request lifecycle states (one-way: queued -> terminal)
QUEUED, DONE, SHED, EXPIRED, FAILED = (
    "queued", "done", "shed", "expired", "failed")


@dataclass
class ServeRequest:
    """One unit of serving work: a window (or opaque payload) for a design.

    ``window`` is a per-request input — for the farm a ``(T, F)`` float
    window; for the generic pool an arbitrary args tuple. Timing fields are
    stamped from the owning component's clock; ``status`` moves exactly
    once from ``queued`` to a terminal state, so "zero dropped after
    admission" is checkable from the request log alone.
    """

    rid: int
    design: str
    window: Any
    t_submit: float = 0.0
    deadline_s: Optional[float] = None   # absolute, on the owner's clock
    status: str = QUEUED
    result: Any = None
    error: Optional[str] = None
    # dispatch provenance (filled by the farm)
    t_done: Optional[float] = None
    member: Optional[int] = None
    bucket_len: Optional[int] = None
    batch_size: Optional[int] = None
    meta: dict = field(default_factory=dict)

    @property
    def terminal(self) -> bool:
        return self.status != QUEUED


class AdmissionQueue:
    """Bounded FIFO with deadline expiry over an injectable clock."""

    def __init__(self, capacity: int, *, clock=time.perf_counter,
                 metrics: Optional[MetricsRegistry] = None,
                 name: str = "serving.queue"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        self.name = name
        self._metrics = metrics
        self._q: Deque[ServeRequest] = deque()

    @property
    def metrics(self) -> MetricsRegistry:
        return self._metrics if self._metrics is not None else get_metrics()

    def __len__(self) -> int:
        return len(self._q)

    def _gauge_depth(self) -> None:
        self.metrics.gauge(f"{self.name}.depth").set(len(self._q))

    # -- admission ------------------------------------------------------ #
    def offer(self, req: ServeRequest) -> bool:
        """Admit ``req`` or shed it at the door. Returns admission."""
        if len(self._q) >= self.capacity:
            req.status = SHED
            req.error = "queue_full"
            self.metrics.counter(f"{self.name}.shed_full").inc()
            return False
        req.t_submit = self.clock() if req.t_submit == 0.0 else req.t_submit
        self._q.append(req)
        self.metrics.counter(f"{self.name}.admitted").inc()
        self._gauge_depth()
        return True

    # -- aging ---------------------------------------------------------- #
    def expire(self) -> List[ServeRequest]:
        """Shed every queued request whose deadline has passed; returns
        the expired requests (already marked terminal).

        The comparison is ``now >= deadline``: a deadline is the last
        instant a *response* may land, so a request first inspected
        exactly at its deadline cannot be served in time — dispatching it
        would burn accelerator work on an already-missed SLO.
        """
        now = self.clock()
        expired: List[ServeRequest] = []
        if not self._q:
            return expired
        keep: Deque[ServeRequest] = deque()
        for req in self._q:
            if req.deadline_s is not None and now >= req.deadline_s:
                req.status = EXPIRED
                req.error = "deadline"
                expired.append(req)
                self.metrics.counter(f"{self.name}.expired").inc()
            else:
                keep.append(req)
        self._q = keep
        if expired:
            self._gauge_depth()
        return expired

    # -- scheduling ----------------------------------------------------- #
    def take(self, n: Optional[int] = None) -> List[ServeRequest]:
        """Pop up to ``n`` requests FIFO (all of them when ``n`` is None)."""
        n = len(self._q) if n is None else min(n, len(self._q))
        out = [self._q.popleft() for _ in range(n)]
        if out:
            self._gauge_depth()
        return out

    def peek(self) -> List[ServeRequest]:
        """The queued requests, oldest first, without removing them."""
        return list(self._q)

    def requeue(self, reqs: List[ServeRequest]) -> None:
        """Put not-yet-dispatched requests back at the front, preserving
        FIFO order (used when the batcher leaves a partial batch to
        linger)."""
        for req in reversed(reqs):
            self._q.appendleft(req)
        if reqs:
            self._gauge_depth()

    def oldest_wait_s(self) -> float:
        """Age of the head request on the queue clock (0 when empty)."""
        if not self._q:
            return 0.0
        return max(0.0, self.clock() - self._q[0].t_submit)
