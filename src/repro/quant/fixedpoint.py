"""Fixed-point (Q-format) quantization — the paper's core optimization.

ElasticAI-Creator translates models to RTL with fixed-point arithmetic
(power-of-two scales, so the FPGA needs only shifts, no multipliers for
rescaling). We reproduce exactly that: Q(total_bits, frac_bits) with
round-to-nearest and saturation, plus a straight-through estimator so the
same graph is trainable (QAT).

On TPU the analogue of the DSP-slice int MAC is the int8 MXU path — see
``repro.quant.ptq`` and ``kernels/quant_matmul`` for that (beyond-paper)
variant; this module is the paper-faithful one.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class FxpFormat:
    """Q(total_bits, frac_bits): 1 sign bit, total-frac-1 integer bits."""

    total_bits: int = 8
    frac_bits: int = 6

    @property
    def scale(self) -> float:
        return float(2 ** self.frac_bits)

    @property
    def lo(self) -> int:
        return -(2 ** (self.total_bits - 1))

    @property
    def hi(self) -> int:
        return 2 ** (self.total_bits - 1) - 1

    @property
    def resolution(self) -> float:
        return 1.0 / self.scale

    @property
    def max_value(self) -> float:
        return self.hi / self.scale

    def __str__(self) -> str:
        return f"Q{self.total_bits}.{self.frac_bits}"


def fxp_quantize(x: jax.Array, fmt: FxpFormat) -> jax.Array:
    """Round-to-nearest, saturating. Returns the *dequantized* f32 value."""
    q = jnp.round(x.astype(jnp.float32) * fmt.scale)
    q = jnp.clip(q, fmt.lo, fmt.hi)
    return q / fmt.scale


def fxp_to_int(x: jax.Array, fmt: FxpFormat) -> jax.Array:
    """The integer codes an RTL template would hold in BRAM."""
    q = jnp.round(x.astype(jnp.float32) * fmt.scale)
    q = jnp.clip(q, fmt.lo, fmt.hi)
    dtype = jnp.int8 if fmt.total_bits <= 8 else jnp.int16 \
        if fmt.total_bits <= 16 else jnp.int32
    return q.astype(dtype)


def fxp_requant_int(v: jax.Array, from_frac: int, fmt: FxpFormat) -> jax.Array:
    """Integer-domain rescale: the exact counterpart of ``fxp_quantize``.

    ``v`` holds integer codes at scale ``2**from_frac``; the result holds the
    codes of ``fxp_quantize(v / 2**from_frac, fmt)`` at scale
    ``2**fmt.frac_bits`` — same round-to-nearest-even and saturation, computed
    entirely in int32 (a shift + comparator, which is what the RTL emits).
    Exactness holds whenever ``|v| < 2**24`` so the float reference's f32
    arithmetic is itself exact (see DESIGN.md §4).
    """
    v = v.astype(jnp.int32)
    s = from_frac - fmt.frac_bits
    if s > 0:                       # narrow: round-half-even right shift
        q0 = jax.lax.shift_right_arithmetic(v, s)
        rem = v - jax.lax.shift_left(q0, s)
        half = 1 << (s - 1)
        inc = (rem > half) | ((rem == half) & ((q0 & 1) == 1))
        q = q0 + inc.astype(jnp.int32)
    elif s < 0:                     # widen: exact left shift
        q = jax.lax.shift_left(v, -s)
    else:
        q = v
    return jnp.clip(q, fmt.lo, fmt.hi)


@jax.custom_vjp
def fxp_fake_quant(x: jax.Array, scale: jax.Array, lo: float, hi: float):
    q = jnp.clip(jnp.round(x * scale), lo, hi)
    return q / scale


def _fq_fwd(x, scale, lo, hi):
    return fxp_fake_quant(x, scale, lo, hi), (x, scale, lo, hi)


def _fq_bwd(res, g):
    x, scale, lo, hi = res
    # STE with saturation masking: no gradient where the value clipped
    inside = (x * scale >= lo) & (x * scale <= hi)
    return (jnp.where(inside, g, 0.0), None, None, None)


fxp_fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quant(x: jax.Array, fmt: FxpFormat) -> jax.Array:
    return fxp_fake_quant(x.astype(jnp.float32), jnp.float32(fmt.scale),
                          float(fmt.lo), float(fmt.hi))


def pick_frac_bits(x: jax.Array, total_bits: int) -> int:
    """Largest frac_bits such that amax still fits (power-of-two scale)."""
    amax = float(jnp.max(jnp.abs(x)))
    if amax == 0.0:
        return total_bits - 1
    import math

    int_bits = max(0, math.ceil(math.log2(amax + 1e-12) + 1e-9) + 1)
    return max(0, min(total_bits - 1, total_bits - 1 - int_bits))


def quant_error(x: jax.Array, fmt: FxpFormat) -> float:
    """RMS quantization error — reported in the creator's stage-1 report."""
    return float(jnp.sqrt(jnp.mean(jnp.square(x - fxp_quantize(x, fmt)))))
