"""Quantization-aware training for translatable components.

The paper's Stage-1 loop: train in the host framework with fake-quantized
weights/activations so the translated fixed-point accelerator matches the
evaluated accuracy. Includes the FPGA-friendly piecewise-linear activation
variants (``hard_sigmoid``/``hard_tanh``) the RTL templates implement as
LUT-free comparators — selectable so developers can measure the accuracy
cost of the cheaper hardware before synthesis.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig
from repro.quant.fixedpoint import FxpFormat, fake_quant


@dataclass(frozen=True)
class QATConfig:
    weight_fmt: FxpFormat = FxpFormat(8, 6)
    act_fmt: FxpFormat = FxpFormat(8, 4)
    accum_fmt: FxpFormat = FxpFormat(16, 8)   # DSP accumulator width
    hard_activations: bool = True             # PWL sigmoid/tanh (RTL-style)
    quantize_activations: bool = True


def hard_sigmoid(x: jax.Array) -> jax.Array:
    """PWL sigmoid: exact at 0/±2.5, slope 0.2 — one comparator + shift-add."""
    return jnp.clip(0.2 * x + 0.5, 0.0, 1.0)


def hard_tanh(x: jax.Array) -> jax.Array:
    return jnp.clip(x, -1.0, 1.0)


def fake_quant_tree(params, fmt: FxpFormat):
    """Fake-quantize every ≥2-D tensor (weights); leave biases full-width."""
    return jax.tree.map(
        lambda p: fake_quant(p, fmt) if p.ndim >= 2 else p, params)


def make_qat_lstm_apply(cfg: ModelConfig, qcfg: QATConfig):
    """Quantized version of the paper's LSTM graph (see model/lstm.py).

    Mirrors what the generated RTL computes: Q-format weights, activations
    re-quantized after every nonlinearity, wide accumulator for the MACs.
    """
    sig = hard_sigmoid if qcfg.hard_activations else jax.nn.sigmoid
    th = hard_tanh if qcfg.hard_activations else jnp.tanh

    def aq(x):
        return fake_quant(x, qcfg.act_fmt) if qcfg.quantize_activations else x

    def cell_step(w, b, x_t, h, c):
        wq = fake_quant(w, qcfg.weight_fmt)
        bq = fake_quant(b, qcfg.accum_fmt)
        z = jnp.concatenate([x_t, h], axis=-1) @ wq + bq
        z = fake_quant(z, qcfg.accum_fmt)          # accumulator truncation
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c_new = aq(sig(f)) * c + aq(sig(i)) * aq(th(g))
        c_new = fake_quant(c_new, qcfg.accum_fmt)
        h_new = aq(sig(o)) * aq(th(c_new))
        return aq(h_new), c_new

    def apply(params, x, state=None):
        c = cfg.lstm
        B, S, _ = x.shape
        seq = aq(x)
        h_states = []
        for li, cell in enumerate(params["cells"]):
            h = jnp.zeros((B, c.hidden), x.dtype) if state is None else state[li][0]
            cc = jnp.zeros((B, c.hidden), x.dtype) if state is None else state[li][1]
            outs = []
            for t in range(S):
                h, cc = cell_step(cell["w"], cell["b"], seq[:, t], h, cc)
                outs.append(h)
            seq = jnp.stack(outs, axis=1)
            h_states.append((h, cc))
        wq = fake_quant(params["head_w"], qcfg.weight_fmt)
        pred = seq[:, -1] @ wq + params["head_b"]
        return pred, tuple(h_states)

    return apply


def make_qat_loss(cfg: ModelConfig, qcfg: QATConfig):
    apply = make_qat_lstm_apply(cfg, qcfg)

    def loss_fn(params, batch):
        pred, _ = apply(params, batch["x"])
        loss = jnp.mean(jnp.square(pred - batch["y"]))
        return loss, {"loss": loss}

    return loss_fn
