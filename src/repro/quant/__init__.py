from repro.quant.fixedpoint import (FxpFormat, fxp_quantize, fxp_fake_quant,
                                    pick_frac_bits)
from repro.quant.ptq import (Int8Params, quantize_params_int8, int8_matmul_ref,
                             dequantize_params)
from repro.quant.qat import (QATConfig, fake_quant_tree, make_qat_lstm_apply,
                             hard_sigmoid, hard_tanh)
