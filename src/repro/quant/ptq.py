"""Post-training int8 quantization — the beyond-paper TPU-native variant.

The paper's FPGA templates use fixed-point MACs in DSP slices; the TPU
analogue is the int8 MXU path. We quantize weights symmetric per-output-
channel to int8 + f32 scales; ``kernels/quant_matmul`` is the Pallas
template that consumes this layout (int8×int8→int32 MAC, rescale on the
way out of VMEM), and :func:`int8_matmul_ref` is its jnp oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass
class Int8Params:
    q: Any        # int8 codes, same tree structure as the source weights
    scale: Any    # f32 per-output-channel scales (1, out) per leaf
    skipped: Any  # leaves kept in full precision (ndim < 2)


def _quant_leaf(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=tuple(range(w.ndim - 1)),
                   keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def quantize_params_int8(params) -> Int8Params:
    flat, tdef = jax.tree.flatten(params)
    qs, scales, skipped = [], [], []
    for leaf in flat:
        if leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            q, s = _quant_leaf(leaf)
            qs.append(q), scales.append(s), skipped.append(None)
        else:
            qs.append(None), scales.append(None), skipped.append(leaf)
    return Int8Params(q=jax.tree.unflatten(tdef, qs),
                      scale=jax.tree.unflatten(tdef, scales),
                      skipped=jax.tree.unflatten(tdef, skipped))


def dequantize_params(ip: Int8Params, dtype=jnp.bfloat16):
    def deq(q, s, skip):
        if q is None:
            return skip
        return (q.astype(jnp.float32) * s).astype(dtype)

    return jax.tree.map(deq, ip.q, ip.scale, ip.skipped,
                        is_leaf=lambda x: x is None)


def int8_matmul_ref(x: jax.Array, wq: jax.Array, scale: jax.Array,
                    act_amax: float = 0.0) -> jax.Array:
    """Oracle for kernels/quant_matmul: dynamic per-tensor activation quant,
    int8×int8→int32 MAC, rescale to f32. x: (..., K), wq: (K, N) int8."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf)) if act_amax == 0.0 else jnp.float32(act_amax)
    xs = jnp.maximum(amax, 1e-8) / 127.0
    xq = jnp.clip(jnp.round(xf / xs), -127, 127).astype(jnp.int8)
    acc = jax.lax.dot_general(
        xq, wq, (((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xs * scale.reshape(1, -1)
